"""Concurrent serving engine (DESIGN.md §10): sharded decode cache,
pread reader pool, readahead, and thread-safe restore surfaces.

The stress tests drive N threads through overlapping restores (full,
iterator, ranged) against one store and assert byte-identity with the
serial path, bounded cache bytes under contention, race-free telemetry,
and absence of deadlock (joins are time-bounded) — including after
compaction and a cold reopen."""
import os
import threading
import time

import numpy as np
import pytest

from repro import api
from repro.api.concurrency import RWLock
from repro.api.restore import DecodeCache, ShardedDecodeCache
from repro.core import delta

CHUNK = 2048
JOIN_S = 120        # deadlock guard: no worker may outlive this


def _build_store_dir(tmp, streams=6, slots=48, seed=0):
    """Version-chained container built straight through the backend (no
    detector cost): stream s's slot j is usually a delta against stream
    s-1's slot j, so cross-stream chains reach depth ~`streams` and
    concurrent restores of different streams share base chains."""
    rng = np.random.default_rng(seed)
    backend = api.FileBackend(tmp)
    expected = {}
    prev_ids = prev_data = None
    next_cid = 0
    for _s in range(streams):
        ids, lens, datas = [], [], []
        for j in range(slots):
            if prev_data is not None and rng.random() < 0.7:
                mix = bytearray(prev_data[j])
                pos = int(rng.integers(0, max(1, len(mix) - 64)))
                mix[pos:pos + 64] = rng.integers(0, 256, 64, np.uint8).tobytes()
                data = bytes(mix)
                patch = delta.encode(data, prev_data[j])
                if len(patch) < len(data):
                    backend.put_delta(next_cid, prev_ids[j], patch, data=data)
                else:
                    backend.put_raw(next_cid, data)
            else:
                data = rng.integers(0, 256, CHUNK, np.uint8).tobytes()
                backend.put_raw(next_cid, data)
            ids.append(next_cid)
            lens.append(len(data))
            datas.append(data)
            next_cid += 1
        expected[backend.add_recipe(ids, lens)] = b"".join(datas)
        prev_ids, prev_data = ids, datas
    backend.close()
    return expected


def _serving_store(tmp, cache_bytes=1 << 20, shards=4):
    return api.build_store(api.DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "file",
        "backend_args": {"path": str(tmp)},
        "restore_cache_bytes": cache_bytes,
        "restore_cache_shards": shards,
        "restore_reader_fds": 4, "restore_readahead": 2}))


def _hammer(store, expected, handles, n_threads=8, rounds=12):
    """N threads × mixed restore surfaces; returns collected errors."""
    errors = []
    done = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(rounds):
                h = int(handles[int(rng.integers(0, len(handles)))])
                want = expected[h]
                mode = int(rng.integers(0, 3))
                if mode == 0:
                    got = store.restore(h)
                elif mode == 1:
                    got = b"".join(store.restore_iter(h, batch_chunks=7))
                else:
                    off = int(rng.integers(0, len(want)))
                    ln = int(rng.integers(0, 4 * CHUNK))
                    assert store.restore_range(h, off, ln) == want[off:off + ln]
                    continue
                assert got == want
            done.append(seed)
        except Exception as e:           # surfaced by the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,), daemon=True)
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_S)
    assert not any(t.is_alive() for t in threads), "deadlocked restore worker"
    assert not errors, errors
    assert len(done) == n_threads
    return errors


def test_concurrent_restores_byte_identical_and_bounded(tmp_path):
    budget = 1 << 20
    expected = _build_store_dir(tmp_path)
    store = _serving_store(tmp_path, cache_bytes=budget)
    handles = sorted(expected)
    before = store.stats.restores
    _hammer(store, expected, handles)
    # race-free aggregate telemetry: every worker op was absorbed exactly
    # once (8 threads x 12 rounds)
    assert store.stats.restores == before + 8 * 12
    # cache-budget ceiling under contention: per-shard eviction holds the
    # aggregate under the global budget (pinned chain working sets stay
    # far below the per-shard slice in this topology)
    assert store.backend.cache_peak_bytes <= budget
    assert store.backend.cache_bytes <= budget
    store.close()


def test_concurrent_restores_after_compaction_and_reopen(tmp_path):
    expected = _build_store_dir(tmp_path, streams=5, slots=32)
    store = _serving_store(tmp_path)
    handles = sorted(expected)
    # concurrent readers on the survivors while the main thread deletes
    # the two oldest streams (exclusive lifecycle lock vs shared fetches)
    survivors = handles[2:]
    t = threading.Thread(
        target=_hammer, args=(store, expected, survivors, 4, 8), daemon=True)
    t.start()
    for h in handles[:2]:
        store.delete(h)
    t.join(JOIN_S)
    assert not t.is_alive()
    run = store.compact()
    assert run.swept_chunks > 0
    _hammer(store, expected, survivors, n_threads=6, rounds=8)
    store.close()

    cold = _serving_store(tmp_path)     # reopen: scan + fresh reader pool
    _hammer(cold, expected, survivors, n_threads=6, rounds=8)
    cold.close()


def test_restore_while_ingesting(tmp_path):
    expected = _build_store_dir(tmp_path, streams=4, slots=24)
    store = _serving_store(tmp_path)
    handles = sorted(expected)
    stop = threading.Event()
    errors = []

    def reader():
        rng = np.random.default_rng(1)
        try:
            while not stop.is_set():
                h = int(handles[int(rng.integers(0, len(handles)))])
                assert store.restore(h) == expected[h]
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=reader, daemon=True) for _ in range(3)]
    for t in threads:
        t.start()
    new = []
    rng = np.random.default_rng(2)
    for _ in range(3):                  # commits interleave with restores
        data = rng.integers(0, 256, 64 << 10, np.uint8).tobytes()
        with store.open_stream() as s:
            s.write(data)
        new.append((s.report.handle, data))
    stop.set()
    for t in threads:
        t.join(JOIN_S)
    assert not any(t.is_alive() for t in threads)
    assert not errors, errors
    for h, data in new:
        assert store.restore(h) == data
    store.close()


def test_per_restore_reports_are_thread_exact(tmp_path):
    """Two cold restores on two threads: each RestoreReport must account
    only its own thread's I/O (global-counter deltas would bleed the
    other restore's bytes in)."""
    expected = _build_store_dir(tmp_path, streams=2, slots=32, seed=3)
    store = _serving_store(tmp_path)
    h0, h1 = sorted(expected)
    reports = {}
    barrier = threading.Barrier(2)

    def one(h):
        barrier.wait()
        data, d = store._fetch_counted(store.backend.recipe(h))
        reports[h] = d

    threads = [threading.Thread(target=one, args=(h,)) for h in (h0, h1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_S)
    for h in (h0, h1):
        read_s, dec_s, bytes_read, hits, misses, prefetch, reqs = reports[h]
        # each stream's container footprint is < 2x its materialized size;
        # a bleed from the sibling restore would roughly double it
        assert 0 < bytes_read < 1.5 * len(expected[h])
        assert misses > 0
        assert reqs > 0                   # physical reads were issued
    total = store.backend.bytes_read      # lifetime totals aggregate both
    assert total == reports[h0][2] + reports[h1][2]
    store.close()


# --- sharded decode cache -----------------------------------------------------

def test_sharded_counters_equal_single_shard_baseline():
    """Satellite: on a serial workload the shard-aggregated counters are
    exactly the single-shard cache's counters (no eviction in play —
    eviction order is the one policy difference sharding introduces)."""
    budget = 1 << 20
    single = DecodeCache(budget)
    sharded = ShardedDecodeCache(budget, shards=4)
    rng = np.random.default_rng(0)
    blobs = {cid: bytes(rng.integers(0, 256, int(rng.integers(100, 2000)),
                                     np.uint8)) for cid in range(64)}
    for cache in (single, sharded):
        for cid, blob in blobs.items():
            cache.put(cid, blob)
        for _ in range(300):
            cache.get(int(rng.integers(0, 96)))     # ~1/3 misses
        rng = np.random.default_rng(0)              # same op stream twice
        blobs = {cid: bytes(rng.integers(0, 256,
                                         int(rng.integers(100, 2000)),
                                         np.uint8)) for cid in range(64)}
    assert sharded.hits == single.hits and sharded.misses == single.misses
    assert sharded.bytes == single.bytes == sum(map(len, blobs.values()))
    assert sharded.peak_bytes == single.peak_bytes
    assert len(sharded) == len(single) == 64


def test_sharded_budget_apportionment_and_eviction():
    budget = 1000
    cache = ShardedDecodeCache(budget, shards=3)
    assert sum(s.budget_bytes for s in cache.shards) == budget
    assert cache.budget_bytes == budget
    for cid in range(60):               # way over budget: LRU must rotate
        cache.put(cid, b"x" * 100)
    assert cache.bytes <= budget
    assert cache.peak_bytes <= budget
    # tiny budgets never produce a zero-budget shard
    tiny = ShardedDecodeCache(3, shards=8)
    assert len(tiny.shards) == 3
    with pytest.raises(ValueError):
        ShardedDecodeCache(0)
    with pytest.raises(ValueError):
        ShardedDecodeCache(100, shards=0)


def test_try_pin_is_atomic_pin_and_fetch():
    cache = ShardedDecodeCache(1 << 10, shards=2)
    assert cache.try_pin(5) is None     # absent: no pin, no counter churn
    assert not cache._pins
    cache.put(5, b"hello")
    assert cache.try_pin(5) == b"hello"
    assert cache._pins == {5: 1}
    # pinned entries survive eviction pressure
    for cid in range(50):
        cache.put(100 + cid, b"z" * 200)
    assert 5 in cache
    cache.unpin(5)
    assert not cache._pins
    # try_pin leaves hit/miss counters alone (planner-probe semantics)
    assert cache.misses == 0 and cache.hits == 0


def test_decode_cache_thread_safety_under_hammering():
    cache = ShardedDecodeCache(64 << 10, shards=4)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(400):
                cid = int(rng.integers(0, 128))
                op = int(rng.integers(0, 4))
                if op == 0:
                    cache.put(cid, bytes(rng.integers(0, 256, 256, np.uint8)))
                elif op == 1:
                    cache.get(cid)
                elif op == 2:
                    data = cache.try_pin(cid)
                    if data is not None:
                        cache.unpin(cid)
                else:
                    cid in cache
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,), daemon=True)
               for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_S)
    assert not any(t.is_alive() for t in threads)
    assert not errors, errors
    assert not cache._pins              # every try_pin was matched
    assert cache.bytes <= cache.budget_bytes


# --- truncated records (satellite bugfix) -------------------------------------

def test_truncated_record_raises_instead_of_short_payload(tmp_path):
    # 1-byte budget: nothing stays cached, every get is a disk read
    backend = api.FileBackend(tmp_path, cache_bytes=1)
    backend.put_raw(0, b"a" * 4096)
    backend.put_raw(1, b"b" * 4096)
    backend.flush()
    _, _, offset, length = backend._index[1]
    os.truncate(tmp_path / "chunks.log", offset + length - 100)
    assert backend.get(0) == b"a" * 4096        # intact record still serves
    with pytest.raises(IOError):
        backend.get(1)
    with pytest.raises(IOError):                 # planned batch path too
        backend.get_many([1])
    assert not backend._cache._pins              # no pins leaked by the raise
    # bytes_read counted what actually arrived, not what was requested
    assert backend.bytes_read < 2 * 4096 + (length - 100) + 1
    backend.close()


def test_reader_pool_parity_with_serial_reads(tmp_path):
    """readahead off vs on: byte-identical results over the same dir."""
    expected = _build_store_dir(tmp_path, streams=3, slots=40, seed=5)
    serial = api.FileBackend(tmp_path, readahead=0, reader_fds=1)
    pooled = api.FileBackend(tmp_path, readahead=3, reader_fds=4)
    for h, want in expected.items():
        r = serial.recipe(h)
        assert b"".join(serial.get_many(r)) == want
        assert b"".join(pooled.get_many(r)) == want
    serial.close()
    pooled.close()


# --- RWLock -------------------------------------------------------------------

def test_rwlock_readers_share_writers_exclude():
    lock = RWLock()
    in_read = threading.Event()
    release_read = threading.Event()
    wrote = []

    def reader():
        with lock.read():
            in_read.set()
            release_read.wait(JOIN_S)

    def writer():
        with lock.write():
            wrote.append(time.monotonic())

    r = threading.Thread(target=reader, daemon=True)
    r.start()
    assert in_read.wait(JOIN_S)
    with lock.read():                   # readers share
        pass
    w = threading.Thread(target=writer, daemon=True)
    w.start()
    time.sleep(0.05)
    assert not wrote                    # writer blocked by active reader
    release_read.set()
    w.join(JOIN_S)
    r.join(JOIN_S)
    assert wrote                        # and admitted once readers drain
    with lock.read():                   # lock is reusable afterwards
        pass


def test_serving_config_knobs_roundtrip_and_forwarding(tmp_path):
    d = {"detector": "dedup-only", "backend": "file",
         "backend_args": {"path": str(tmp_path)},
         "restore_cache_bytes": 1 << 20, "restore_cache_shards": 3,
         "restore_reader_fds": 2, "restore_readahead": 0}
    cfg = api.DedupConfig.from_dict(d)
    assert api.DedupConfig.from_dict(cfg.to_dict()) == cfg
    store = api.build_store(cfg)
    assert len(store.backend._cache.shards) == 3
    assert store.backend._cache.budget_bytes == 1 << 20
    assert store.backend._pool.size == 2
    assert store.backend._readahead == 0
    store.close()
    for bad in ({"restore_cache_shards": 0}, {"restore_reader_fds": 0},
                {"restore_readahead": -1}, {"restore_cache_bytes": 0}):
        with pytest.raises(ValueError):
            api.DedupConfig.from_dict({**d, **bad})
    # memory backend has no serving knobs: they are skipped, not passed
    mem = api.build_store(api.DedupConfig.from_dict(
        {"detector": "dedup-only", "restore_cache_bytes": 1 << 20,
         "restore_readahead": 4}))
    assert isinstance(mem.backend, api.InMemoryBackend)
    mem.close()


def test_restore_iter_prefetch_and_abandonment(tmp_path):
    expected = _build_store_dir(tmp_path, streams=2, slots=64, seed=7)
    store = _serving_store(tmp_path)
    h = sorted(expected)[-1]
    want = expected[h]
    pieces = list(store.restore_iter(h, batch_chunks=8))    # many batches
    assert b"".join(pieces) == want
    report = store.last_restore
    assert report.handle == h and report.bytes_out == len(want)
    n = store.stats.restores
    it = store.restore_iter(h, batch_chunks=8)
    next(it)
    it.close()                          # abandoned: no report, no crash
    assert store.stats.restores == n
    assert store.restore(h) == want     # store fully usable afterwards
    store.close()


def test_restore_after_close_raises_cleanly(tmp_path):
    """close() contract: resuming a partially consumed restore_iter (or
    any new restore) after close raises RuntimeError — it must neither
    recreate the drained prefetch pool (a leaked executor) nor reach the
    closed backend's empty reader-fd pool (ZeroDivisionError)."""
    expected = _build_store_dir(tmp_path, streams=2, slots=32, seed=11)
    store = _serving_store(tmp_path)
    h = sorted(expected)[-1]
    it = store.restore_iter(h, batch_chunks=4)
    next(it)
    store.close()
    with pytest.raises(RuntimeError):
        list(it)
    assert store._prefetch is None      # no pool resurrected by the resume
    with pytest.raises(RuntimeError):
        store.restore(h)
    # the contract is uniform across surfaces: mutations fail the same
    # way, before touching (and partially mutating) the closed backend
    with pytest.raises(RuntimeError):
        store.ingest(b"post-close data")
    with pytest.raises(RuntimeError):
        store.delete(h)
    with pytest.raises(RuntimeError):
        store.compact()
    store.close()                       # idempotent
