"""Systematic crash-consistency matrix (DESIGN.md §13.4): kill the
process at every registered fsync/rename/PUT boundary, snapshot the
directory as a ``kill -9`` left it, reopen, scrub, and assert the
post-crash contract — committed streams restore byte-identically,
deleted streams stay deleted, the in-flight op is all-or-nothing."""
import os

import numpy as np
import pytest

from repro import api
from repro.api import faults as F
import repro.api.objectstore  # noqa: F401 - registers objstore.* crashpoints


def _data(size, seed):
    rng = np.random.default_rng(seed)
    return bytes(rng.integers(0, 256, size, np.uint8))


def _build(backend, root, injector=None):
    args = {"path": str(root)}
    if injector is not None:
        args["faults"] = injector
    return api.build_store(api.DedupConfig.from_dict(
        {"detector": "card", "backend": backend, "backend_args": args}))


# every lifecycle transition the crashpoints guard: ingest (fresh +
# resembling), delete, collect, compact, ingest-after-compact, flush
_SCRIPT_SEEDS = (1, 2, 3)


def _script():
    d1 = _data(120_000, _SCRIPT_SEEDS[0])
    d2 = d1[:60_000] + _data(20_000, _SCRIPT_SEEDS[1]) + d1[60_000:]
    d3 = _data(90_000, _SCRIPT_SEEDS[2])
    return d1, [("ingest", "a", d1),
                ("ingest", "b", d2),
                ("delete", "a"),
                ("collect",),
                ("compact",),
                ("ingest", "c", d3),
                ("flush",)]


def _crash_once(backend, point, tmp_path, ordinal=1):
    """Arm ``point``, run the script to the crash, snapshot, reopen the
    snapshot, return (run, invariant_errors, fired?)."""
    root = tmp_path / "store"
    snap = tmp_path / "snap"
    inj = F.FaultInjector()
    store = _build(backend, root, inj)
    train, ops = _script()
    store.fit([train])
    inj.arm(point, ordinal)
    run = F.run_crash_script(store, ops)
    F.snapshot_dir(root, snap)
    F.abandon(store)
    if run.crashed_at is None:
        return run, [], False
    assert run.crashed_at == point
    reopened = _build(backend, snap)
    errors = F.check_crash_invariants(reopened, run)
    reopened.close()
    return run, errors, True


_FILE_POINTS = sorted(p for p in F.registered_crashpoints()
                      if p.startswith("file."))
_OBJ_POINTS = sorted(p for p in F.registered_crashpoints()
                     if p.startswith("objstore."))


def test_matrix_is_fully_registered():
    reg = F.registered_crashpoints()
    assert len(_FILE_POINTS) == 7 and len(_OBJ_POINTS) == 8
    assert all(reg[p] for p in reg)       # every row has a description


@pytest.mark.parametrize("point", _FILE_POINTS)
def test_file_backend_crash(point, tmp_path):
    run, errors, fired = _crash_once("file", point, tmp_path)
    assert fired, f"script never reached {point}"
    assert errors == []


@pytest.mark.parametrize("point", _OBJ_POINTS)
def test_objectstore_backend_crash(point, tmp_path):
    run, errors, fired = _crash_once("objectstore", point, tmp_path)
    assert fired, f"script never reached {point}"
    assert errors == []


def test_second_ordinal_crash(tmp_path):
    """Crashing at the *second* hit of a hot boundary exercises a
    different store state than the first."""
    run, errors, fired = _crash_once("file", "file.flush.before_fsync",
                                     tmp_path, ordinal=2)
    assert fired and errors == []


def test_unarmed_injector_never_fires(tmp_path):
    inj = F.FaultInjector()
    store = _build("file", tmp_path / "s", inj)
    train, ops = _script()
    store.fit([train])
    run = F.run_crash_script(store, ops)
    assert run.crashed_at is None and run.pending is None
    assert inj.fired == []
    assert inj.hits                        # boundaries were crossed
    assert store.scrub().clean
    store.close()


def test_injector_rejects_unknown_point():
    inj = F.FaultInjector()
    with pytest.raises(ValueError):
        inj.arm("no.such.point")
    with pytest.raises(ValueError):
        inj.arm(_FILE_POINTS[0], ordinal=0)


def test_simulated_crash_is_base_exception():
    # an `except Exception` recovery path must not absorb the signal
    assert not issubclass(F.SimulatedCrash, Exception)
    assert issubclass(F.SimulatedCrash, BaseException)


# --- randomized sweep (hypothesis, when available) ---------------------------
# guarded per-test (not a module-level importorskip) so the
# deterministic matrix above always runs

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:         # pragma: no cover - env-dependent
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(pick=st.integers(min_value=0, max_value=10**9),
           ordinal=st.integers(min_value=1, max_value=3))
    def test_random_point_and_ordinal(pick, ordinal, tmp_path_factory):
        points = _FILE_POINTS + _OBJ_POINTS
        point = points[pick % len(points)]
        backend = "file" if point.startswith("file.") else "objectstore"
        tmp = tmp_path_factory.mktemp("crash")
        run, errors, fired = _crash_once(backend, point, tmp, ordinal)
        # high ordinals may never be reached — that is a legal outcome;
        # a fired crash must still reopen to a contract-honouring store
        if fired:
            assert errors == []
        else:
            assert run.crashed_at is None
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_point_and_ordinal():
        pass
