"""Property test for §15.1 tenant quota accounting: per-tenant byte
charges must track ingest/delete/compact interleavings with zero drift
against ``StoreStats``. Lives in its own module (like
``test_lifecycle_property.py``) so environments without hypothesis
skip only this file, never the directed serve suite."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import api  # noqa: E402
from repro.api.serve import DedupServer  # noqa: E402

_PAYLOADS = [bytes([65 + i]) * (1500 + 977 * i) for i in range(6)]
_OPS = st.lists(
    st.tuples(st.integers(0, 2),
              st.sampled_from(["ingest", "delete", "compact"]),
              st.integers(0, 5)),
    min_size=1, max_size=24)


@settings(max_examples=20, deadline=None)
@given(ops=_OPS)
def test_tenant_byte_charges_never_drift_from_store_stats(ops):
    """§15.1 accounting invariants, after *every* op in any
    ingest/delete/compact interleaving: (1) the sum of per-tenant
    lifetime charges equals ``StoreStats.bytes_stored`` exactly, (2)
    each tenant's live charge equals the commit-time cost of its live
    handles, and (3) every live stream restores byte-identically."""
    store = api.build_store(api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "memory"}))
    srv = DedupServer(store, workers=2)
    live = {0: [], 1: [], 2: []}
    try:
        for tidx, kind, pidx in ops:
            tenant = f"t{tidx}"
            if kind == "ingest":
                rep = srv.ingest(tenant, _PAYLOADS[pidx])
                live[tidx].append((rep.handle, _PAYLOADS[pidx],
                                   rep.bytes_stored))
            elif kind == "delete":
                if not live[tidx]:
                    continue
                handle, _, _ = live[tidx].pop(pidx % len(live[tidx]))
                srv.delete(tenant, handle)
            else:
                store.collect()
                store.compact()
            lifetime = sum(srv.tenant_stats(f"t{i}")["bytes_ingested"]
                           for i in range(3))
            assert lifetime == store.stats.bytes_stored
            for i in range(3):
                assert (srv.tenant_stats(f"t{i}")["bytes_stored"]
                        == sum(cost for _, _, cost in live[i]))
        for i in range(3):
            for handle, data, _ in live[i]:
                assert srv.restore(f"t{i}", handle) == data
    finally:
        srv.close(close_store=True)
