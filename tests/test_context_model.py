"""BP-NN chunk-context model: training converges, formulas are faithful,
transform preserves/improves matchability."""
import numpy as np
import pytest

from repro.core import context_model


def _stream_features(t=400, m=64, seed=0):
    """Synthetic feature stream with co-occurrence structure: repeated motifs."""
    rng = np.random.Generator(np.random.PCG64(seed))
    motifs = rng.standard_normal((10, 5, m)).astype(np.float32)
    motifs /= np.linalg.norm(motifs, axis=-1, keepdims=True)
    rows = []
    while len(rows) < t:
        mi = rng.integers(0, 10)
        noise = rng.standard_normal((5, m)).astype(np.float32) * 0.05
        rows.extend(motifs[mi] + noise)
    return np.stack(rows[:t])


def test_training_reduces_loss():
    feats = _stream_features()
    cfg = context_model.ContextModelConfig(m=64, d=50, steps=200)
    model = context_model.ContextModel(cfg).fit(feats)
    first = np.mean(model.losses[:10])
    last = np.mean(model.losses[-10:])
    assert last < 0.5 * first


def test_transform_shapes_and_norm():
    feats = _stream_features(t=100)
    model = context_model.ContextModel(
        context_model.ContextModelConfig(m=64, d=40, steps=50)).fit(feats)
    out = model.transform(feats[:7])
    assert out.shape == (7, 40)
    np.testing.assert_allclose(np.linalg.norm(out, axis=1), 1.0, rtol=1e-4)


def test_transform_keeps_similar_close():
    feats = _stream_features(t=300, seed=3)
    model = context_model.ContextModel(
        context_model.ContextModelConfig(m=64, d=50, steps=200)).fit(feats)
    base = feats[10]
    near = base + 0.05 * np.random.Generator(np.random.PCG64(4)).standard_normal(64).astype(np.float32)
    far = np.random.Generator(np.random.PCG64(5)).standard_normal(64).astype(np.float32)
    t = model.transform(np.stack([base, near, far]))
    assert t[0] @ t[1] > 0.85
    assert t[0] @ t[1] > t[0] @ t[2] + 0.2


def test_make_training_pairs_edges():
    feats = np.arange(12, dtype=np.float32).reshape(6, 2)
    ctx, tgt = context_model.make_training_pairs(feats, k=2)
    assert ctx.shape == tgt.shape == (6, 2)
    # row 0 context = mean(rows 1, 2)
    np.testing.assert_allclose(ctx[0], feats[1:3].mean(0))
    # middle row context = mean of 4 neighbours
    np.testing.assert_allclose(ctx[3], feats[[1, 2, 4, 5]].mean(0))


def test_formula_scaling_literal():
    """Formulas 1-3: the 2K / (1/2K) factors must cancel through transform."""
    feats = _stream_features(t=120, seed=6)
    cfg = context_model.ContextModelConfig(m=64, d=30, steps=30, k=3)
    model = context_model.ContextModel(cfg).fit(feats)
    f = feats[:4]
    import jax.numpy as jnp
    manual = (2 * cfg.k) * (f @ np.asarray(model._u_pinv))
    manual /= np.linalg.norm(manual, axis=1, keepdims=True) + 1e-12
    np.testing.assert_allclose(model.transform(f), manual, rtol=1e-4)
