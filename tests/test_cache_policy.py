"""Scan-resistant tiered cache hierarchy (DESIGN.md §14): pluggable
eviction policies (lru/arc parity + scan resistance), per-shard budget
ceilings, cold-decode singleflight (decode counters under a thread
race), the local-disk tier (reopen survival, corrupt-entry refetch),
heat-aware compaction placement, streaming-scrub request savings, and
the new Prometheus families' round-trip."""
import threading

import numpy as np
import pytest

from repro.api.config import DedupConfig, build_store
from repro.api.containers import FileBackend
from repro.api.lifecycle import _placement_order
from repro.api.objectstore import DiskTierCache, ObjectStoreBackend
from repro.api.observe import parse_prometheus_text
from repro.api.registry import available_cache_policies, get_cache_policy
from repro.api.restore import (ArcCachePolicy, DecodeCache, LruCachePolicy,
                               ShardedDecodeCache)
from repro.core import delta


# --- fixtures ----------------------------------------------------------------

def _blobs(n, size=3000, seed=0):
    rng = np.random.default_rng(seed)
    return {i: bytes(rng.integers(0, 256, size, np.uint8)) for i in range(n)}


def _populate(backend, blobs, raw_n):
    """First ``raw_n`` chunks raw, the rest delta-chained onto them;
    one recipe per half. Returns (h0, h1)."""
    n = len(blobs)
    backend.put_many([(i, -1, blobs[i], None) for i in range(raw_n)])
    backend.put_many([(i, i - raw_n,
                       delta.encode(blobs[i], blobs[i - raw_n]), blobs[i])
                      for i in range(raw_n, n)])
    h0 = backend.add_recipe(list(range(raw_n)),
                            [len(blobs[i]) for i in range(raw_n)])
    h1 = backend.add_recipe(list(range(raw_n, n)),
                            [len(blobs[i]) for i in range(raw_n, n)])
    backend.flush()
    return h0, h1


def _cold(backend):
    backend._cache.retain(lambda cid: False)


def _store(tmp_path, name, **knobs):
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "file",
        "backend_args": {"path": str(tmp_path / name)},
        "chunker_args": {"avg_size": 2048}, **knobs})
    return build_store(cfg)


# --- policy registry + config knobs ------------------------------------------

def test_cache_policy_registry():
    assert {"lru", "arc"} <= set(available_cache_policies())
    assert get_cache_policy("lru") is LruCachePolicy
    assert get_cache_policy("arc") is ArcCachePolicy
    with pytest.raises(KeyError):
        get_cache_policy("clock")


def test_cache_policy_knob_validation(tmp_path):
    with pytest.raises(TypeError):
        DedupConfig.from_dict({"restore_cache_policy": 7})
    with pytest.raises(TypeError):
        DedupConfig.from_dict({"restore_tier_path": 7})
    with pytest.raises(ValueError):
        DedupConfig.from_dict({"restore_tier_bytes": 0})
    store = _store(tmp_path, "f", restore_cache_policy="arc")
    assert store.backend._cache.policy_name == "arc"
    store.close()
    with pytest.raises(KeyError):        # unknown name fails at build
        build_store(DedupConfig.from_dict({
            "detector": "dedup-only", "backend": "file",
            "backend_args": {"path": str(tmp_path / "g")},
            "restore_cache_policy": "clock"}))


# --- policy parity: restores byte-identical under every policy ---------------

@pytest.mark.parametrize("policy", ["lru", "arc"])
def test_policy_restore_byte_identity(tmp_path, policy):
    """A tiny cache forces constant eviction; every policy must still
    restore byte-identically (policies order eviction, never bytes)."""
    blobs = _blobs(24, size=4000, seed=3)
    backend = FileBackend(tmp_path / policy, cache_bytes=10_000,
                          cache_shards=2, cache_policy=policy)
    h0, h1 = _populate(backend, blobs, 12)
    for _ in range(3):                  # repeat: hits + evictions interleave
        got = backend.get_many(list(range(24)))
        assert got == [blobs[i] for i in range(24)]
    assert backend._cache.evictions > 0
    backend.close()


def test_lru_matches_inlined_behaviour():
    """The extracted lru policy preserves the pre-§14 inlined ordering:
    oldest unpinned evicts first, get refreshes recency, pins skip."""
    cache = DecodeCache(budget_bytes=30, policy="lru")
    cache.put(1, b"x" * 10)
    cache.put(2, b"y" * 10)
    cache.put(3, b"z" * 10)
    assert cache.get(1) is not None     # refresh 1: 2 is now oldest
    cache.put(4, b"w" * 10)             # evicts 2
    assert cache.peek(2) is None and cache.peek(1) is not None
    cache.pin(3)
    cache.put(5, b"v" * 20)             # needs 2 evictions; 3 is pinned
    assert cache.peek(3) is not None and cache.peek(5) is not None
    assert cache.ghost_hits == 0        # lru keeps no ghosts


# --- arc: scan resistance ----------------------------------------------------

def test_arc_scan_does_not_evict_hot_set():
    """Chunks referenced twice live in T2; a one-touch scan flows
    through T1 and must not displace them (the §14.1 argument). Under
    lru the same scan evicts the whole hot set."""
    def run(policy):
        cache = DecodeCache(budget_bytes=100, policy=policy)
        for cid in range(5):            # hot set: 50 bytes, touched again
            cache.put(cid, b"h" * 10)
        for cid in range(5):
            assert cache.get(cid) is not None
        for cid in range(100, 140):     # 400-byte one-touch scan
            cache.put(cid, b"s" * 10)
        return sum(cache.peek(cid) is not None for cid in range(5))

    assert run("lru") == 0              # scan flushed everything
    assert run("arc") >= 4              # T2 survived the scan

def test_arc_ghost_hit_adapts_and_counts():
    pol = ArcCachePolicy(budget_bytes=20)
    pol.on_insert(1, 10)
    pol.on_insert(2, 10)
    assert pol.victim(lambda c: False) == 1     # oldest T1 -> B1 ghost
    assert pol.evictions == 1
    pol.on_insert(1, 10)                # miss on a B1 ghost
    assert pol.ghost_hits == 1
    assert pol._p == 10                 # recency side earned bytes
    assert 1 in pol._t2                 # reinserted as frequent
    pol.on_remove(1)                    # invalidation: no ghost left
    assert 1 not in pol._b1 and 1 not in pol._b2


def test_arc_all_pinned_returns_none():
    cache = DecodeCache(budget_bytes=20, policy="arc")
    cache.put(1, b"x" * 10, pin=True)
    cache.put(2, b"y" * 10, pin=True)
    cache.put(3, b"z" * 30)             # over budget, nothing evictable
    assert cache.peek(1) is not None and cache.peek(2) is not None


# --- sharded budget ceiling --------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "arc"])
def test_sharded_budget_ceiling(policy):
    budget = 64 << 10
    cache = ShardedDecodeCache(budget_bytes=budget, shards=4, policy=policy)
    rng = np.random.default_rng(11)
    for cid in range(300):
        cache.put(cid, bytes(rng.integers(0, 256, 1024, np.uint8)))
        assert cache.bytes <= budget
    assert cache.peak_bytes <= budget
    assert cache.evictions > 0
    assert cache.policy_name == policy


# --- cold-decode singleflight ------------------------------------------------

def test_singleflight_race_decode_counters(tmp_path, monkeypatch):
    """N threads cold-restoring the same delta-heavy recipe: decodes
    collapse to roughly one per chunk (bounded slack for the deadlock-
    avoiding ownership fallback), waits/collapses are counted, and every
    thread gets byte-identical data. A slowed decode pins the overlap
    the race needs — local preads alone finish before contention."""
    import time as _time

    from repro.api import containers as cmod
    real_decode = cmod.delta.decode

    def slow_decode(patch, base):
        _time.sleep(0.002)
        return real_decode(patch, base)

    monkeypatch.setattr(cmod.delta, "decode", slow_decode)
    blobs = _blobs(16, size=6000, seed=7)
    backend = FileBackend(tmp_path / "sf", cache_bytes=32 << 20)
    _populate(backend, blobs, 4)
    want = [blobs[i] for i in range(16)]
    nthreads = 4
    barrier = threading.Barrier(nthreads)
    results, errors = [None] * nthreads, []

    def worker(i):
        try:
            barrier.wait()
            results[i] = backend.get_many(list(range(16)))
        except Exception as e:          # pragma: no cover - fail loudly
            errors.append(e)

    _cold(backend)
    backend.decoded_chunks = 0
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert all(r == want for r in results)
    # decode-once up to the rare ownership fallback: far below the
    # nthreads * unique a raceable cache would pay
    assert backend.decoded_chunks <= 2 * len(blobs)
    assert backend._sf_waits + backend._sf_collapsed > 0
    backend.close()


def test_singleflight_off_still_correct(tmp_path):
    blobs = _blobs(8, size=4000, seed=9)
    backend = FileBackend(tmp_path / "nosf", singleflight=False)
    _populate(backend, blobs, 2)
    _cold(backend)
    assert backend.get_many(list(range(8))) == [blobs[i] for i in range(8)]
    assert backend._sf_waits == 0 and backend._sf_collapsed == 0
    backend.close()


# --- local-disk tier ---------------------------------------------------------

def _tier_backend(tmp_path, **kw):
    return ObjectStoreBackend(tmp_path / "o",
                              tier_path=tmp_path / "tier",
                              tier_bytes=8 << 20, **kw)


def test_disk_tier_serves_and_survives_reopen(tmp_path):
    blobs = _blobs(12, size=5000, seed=13)
    b0 = _tier_backend(tmp_path)
    _populate(b0, blobs, 6)
    _cold(b0)
    assert b0.get_many(list(range(12))) == [blobs[i] for i in range(12)]
    assert b0._tier.bytes_filled > 0    # cold read fed the tier
    b0.close()

    b1 = _tier_backend(tmp_path)        # reopen: tier adopted from disk
    assert len(b1._tier) > 0
    gets_before = b1.client.op_counts["get"]
    assert b1.get_many(list(range(12))) == [blobs[i] for i in range(12)]
    assert b1._tier.hits > 0
    # tier hits replace remote payload GETs (journal/manifest reads and
    # sub-span fills remain)
    assert b1.client.op_counts["get"] - gets_before < b1._tier.hits + 12
    b1.close()


def test_disk_tier_corrupt_entry_refetches(tmp_path):
    """A bit-flipped tier file must never be served: the lazy crc
    re-verify drops it (dropped counter) and the read refetches from
    the store, byte-identical."""
    blobs = _blobs(6, size=4000, seed=17)
    b0 = _tier_backend(tmp_path)
    _populate(b0, blobs, 3)
    _cold(b0)
    b0.get_many(list(range(6)))
    b0.close()

    victim = 2
    path = DiskTierCache(tmp_path / "tier", 8 << 20)._path(victim)
    raw = bytearray(path.read_bytes())
    raw[0] ^= 0xFF
    path.write_bytes(bytes(raw))

    b1 = _tier_backend(tmp_path)
    assert b1.get_many(list(range(6))) == [blobs[i] for i in range(6)]
    assert b1._tier.dropped >= 1
    b1.close()


def test_disk_tier_respects_budget(tmp_path):
    tier = DiskTierCache(tmp_path / "t", budget_bytes=10_000, policy="lru")
    rng = np.random.default_rng(19)
    from repro.api.integrity import crc32c
    for cid in range(20):
        payload = bytes(rng.integers(0, 256, 1000, np.uint8))
        tier.put(cid, payload, crc32c(payload))
        assert tier.bytes <= 10_000
    assert len(tier) <= 10
    tier.put(99, b"x" * 100, None)      # no journaled crc: never tiered
    assert tier.get(99, None) is None


def test_disk_tier_retain_after_compaction(tmp_path):
    """Compaction rebases patches (same cid, new bytes): retain must
    force every surviving entry through a fresh crc check so stale
    pre-rebase bytes can never be served against the new journal crc."""
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "restore_tier_path": str(tmp_path / "tier"),
        "chunker_args": {"avg_size": 2048}})
    store = build_store(cfg)
    rng = np.random.default_rng(23)
    base = rng.integers(0, 256, 48 << 10, np.uint8).tobytes()
    edited = base[: 24 << 10] + rng.integers(0, 256, 24 << 10,
                                             np.uint8).tobytes()
    handles = []
    for data in (base, edited):
        with store.open_stream() as s:
            s.write(data)
        handles.append(s.report.handle)
    _cold(store.backend)
    assert store.restore(handles[1]) == edited      # tier filled
    store.delete(handles[0])
    store.compact()
    _cold(store.backend)
    assert store.restore(handles[1]) == edited      # post-rebase identity
    store.close()


# --- heat-aware compaction placement -----------------------------------------

def test_placement_order_groups_hot_chains_first():
    # two chains: 1 <- 2 <- 3 and 10 <- 11; chain 10 is hotter
    keep = {1, 2, 3, 10, 11}
    base_of = {1: -1, 2: 1, 3: 2, 10: -1, 11: 10}.__getitem__
    heat = {10: 50, 11: 50, 2: 5}
    assert _placement_order(keep, {}, base_of, heat) == [10, 11, 1, 2, 3]
    # no heat: byte-stable sorted order
    assert _placement_order(keep, {}, base_of, {}) == [1, 2, 3, 10, 11]
    # a rebase moves 11 onto 1: placement follows the post-rebase chain
    rebases = {11: (1, 1, b"p")}
    assert _placement_order(keep, rebases, base_of, heat)[:4] == [1, 2, 3, 11]


def test_compact_places_hot_chain_contiguously(tmp_path):
    blobs = _blobs(12, size=3000, seed=29)
    backend = FileBackend(tmp_path / "heat")
    _populate(backend, blobs, 6)
    for _ in range(10):                 # heat up the second recipe's chain
        backend.get_many([7, 8])
    heat = backend.chunk_heat()
    assert heat[7] == 10 and heat[8] == 10

    class _Store:                       # minimal lifecycle test double
        pass

    from repro.api.lifecycle import compact
    from repro.api.refcount import RefcountTable
    st = _Store()
    st.backend = backend
    st._refs = RefcountTable.rebuild(backend)
    st._by_digest = {}
    st._refresh_lifecycle_stats = lambda: None
    st._compact_skipped_at = None
    import types
    st.stats = types.SimpleNamespace(reclaimed_bytes=0)
    compact(st)
    # the hot patches' chain (bases 1,2 + patches 7,8) leads the log
    order = sorted(backend._index, key=lambda c: backend._index[c][2])
    assert set(order[:4]) == {1, 7, 2, 8}
    assert backend.get_many(list(range(12))) == \
        [blobs[i] for i in range(12)]
    backend.close()


# --- streaming scrub ---------------------------------------------------------

def test_scrub_stream_saves_requests(tmp_path):
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "chunker_args": {"avg_size": 2048}})
    store = build_store(cfg)
    rng = np.random.default_rng(31)
    data = rng.integers(0, 256, 64 << 10, np.uint8).tobytes()
    with store.open_stream() as s:
        s.write(data)
    report = store.scrub()
    assert report.clean
    assert report.payload_requests_naive == report.chunks
    assert 0 < report.payload_requests < report.payload_requests_naive
    store.close()


def test_scrub_per_chunk_fallback_counts_naive(tmp_path):
    store = _store(tmp_path, "f")
    with store.open_stream() as s:
        s.write(b"ab" * 4096)
    report = store.scrub()
    assert report.clean
    assert report.payload_requests == report.payload_requests_naive \
        == report.chunks
    store.close()


# --- observability round-trip ------------------------------------------------

def test_cache_hierarchy_prometheus_round_trip(tmp_path):
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "restore_cache_bytes": 1 << 20, "restore_cache_policy": "arc",
        "restore_tier_path": str(tmp_path / "tier"),
        "chunker_args": {"avg_size": 2048}})
    store = build_store(cfg)
    rng = np.random.default_rng(37)
    data = rng.integers(0, 256, 128 << 10, np.uint8).tobytes()
    with store.open_stream() as s:
        s.write(data)
    h = s.report.handle
    _cold(store.backend)
    assert store.restore(h) == data
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    names = {n for n, _, _ in parsed["samples"]}
    for name in ("repro_cache_ghost_hits_total",
                 "repro_cache_evictions_total",
                 "repro_singleflight_total",
                 "repro_tier_lookups_total",
                 "repro_tier_bytes_total",
                 "repro_tier_dropped_total",
                 "repro_tier_bytes"):
        assert name in names, name
    stats = store.cache_stats()
    assert stats["policy"] == "arc"
    assert stats["decoded_chunks"] > 0
    assert stats["tier"] is not None
    assert stats["tier"]["bytes_filled"] > 0
    store.close()
