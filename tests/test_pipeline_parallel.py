"""GPipe pipeline parallelism: pipelined == sequential oracle, grads flow."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=Path.cwd(), timeout=540)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


@pytest.mark.subprocess_mesh
def test_pipeline_matches_sequential():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, reference_apply
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("pod",))
        rng = jax.random.PRNGKey(0)
        S, D = 4, 16
        params = {"w": jax.random.normal(rng, (S, D, D)) * 0.3,
                  "b": jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1}

        def stage(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        x = jax.random.normal(jax.random.PRNGKey(2), (8, D))
        with mesh:
            y = pipeline_apply(stage, params, x, mesh=mesh, axis="pod",
                               num_microbatches=4)
        want = reference_apply(stage, params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        # gradients flow through the ppermute chain
        def loss(p):
            with mesh:
                return jnp.sum(pipeline_apply(stage, p, x, mesh=mesh,
                                              axis="pod") ** 2)
        g = jax.grad(loss)(params)
        gw = np.asarray(g["w"])
        assert np.isfinite(gw).all()
        assert (np.abs(gw).sum(axis=(1, 2)) > 0).all()  # every stage gets grad
        print("PIPELINE_OK")
    """))
    assert "PIPELINE_OK" in out
