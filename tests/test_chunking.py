"""FastCDC: parallel candidate scan must equal the serial reference, and
chunk-size invariants must hold on arbitrary inputs."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import chunking


CFG = chunking.ChunkerConfig(avg_size=1024)


@given(st.binary(min_size=0, max_size=60_000))
@settings(max_examples=20, deadline=None)
def test_parallel_matches_serial(data):
    chunks = chunking.chunk_stream(data, CFG)
    par = np.concatenate([[0], np.cumsum([c.length for c in chunks])]) \
        if chunks else np.array([0])
    ser = chunking.chunk_boundaries_serial(data, CFG) if data else np.array([0])
    assert np.array_equal(par, ser)


@given(st.binary(min_size=1, max_size=60_000))
@settings(max_examples=20, deadline=None)
def test_size_invariants_and_reassembly(data):
    chunks = chunking.chunk_stream(data, CFG)
    assert b"".join(c.data for c in chunks) == data
    for c in chunks[:-1]:
        assert CFG.min_size <= c.length <= CFG.max_size
    assert chunks[-1].length <= CFG.max_size


def test_boundary_shift_resync():
    """Content-defined boundaries must re-synchronize after an insertion."""
    rng = np.random.Generator(np.random.PCG64(7))
    base = rng.integers(0, 256, size=100_000, dtype=np.uint8).tobytes()
    edited = base[:5_000] + b"xxxx" + base[5_000:]
    a = {c.digest for c in chunking.chunk_stream(base, CFG)}
    b = {c.digest for c in chunking.chunk_stream(edited, CFG)}
    # everything beyond the first few chunks should dedup exactly
    assert len(a & b) >= len(a) - 3


@pytest.mark.parametrize("avg", [512, 4096, 16384])
def test_avg_size_tracks_config(avg):
    rng = np.random.Generator(np.random.PCG64(8))
    data = rng.integers(0, 256, size=64 * avg, dtype=np.uint8).tobytes()
    cfg = chunking.ChunkerConfig(avg_size=avg)
    chunks = chunking.chunk_stream(data, cfg)
    mean = np.mean([c.length for c in chunks])
    assert 0.4 * avg <= mean <= 2.5 * avg


def test_precomputed_hashes_equivalent():
    from repro.core import hashing
    rng = np.random.Generator(np.random.PCG64(9))
    data = rng.integers(0, 256, size=30_000, dtype=np.uint8)
    h = hashing.gear_hashes_np(data)
    a = chunking.chunk_stream(data.tobytes(), CFG)
    b = chunking.chunk_stream(data.tobytes(), CFG, hashes=h)
    assert [c.length for c in a] == [c.length for c in b]
