"""Delta codec: byte-identical roundtrip on arbitrary inputs (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import delta


@given(st.binary(min_size=0, max_size=5000), st.binary(min_size=0, max_size=5000))
@settings(max_examples=50, deadline=None)
def test_roundtrip_arbitrary(target, base):
    assert delta.decode(delta.encode(target, base), base) == target


@given(st.binary(min_size=100, max_size=5000),
       st.integers(min_value=0, max_value=99),
       st.binary(min_size=0, max_size=50))
@settings(max_examples=50, deadline=None)
def test_roundtrip_edit(base, pos, insert)        :
    target = base[:pos] + insert + base[pos + 3:]
    assert delta.decode(delta.encode(target, base), base) == target


def test_similar_compresses_well():
    rng = np.random.Generator(np.random.PCG64(10))
    base = rng.integers(0, 256, size=100_000, dtype=np.uint8).tobytes()
    target = base[:40_000] + b"PATCH" + base[40_000:]
    d = delta.encode(target, base)
    assert len(d) < 200

def test_identical_is_tiny():
    rng = np.random.Generator(np.random.PCG64(11))
    base = rng.integers(0, 256, size=50_000, dtype=np.uint8).tobytes()
    assert len(delta.encode(base, base)) < 32


def test_dissimilar_no_blowup():
    rng = np.random.Generator(np.random.PCG64(12))
    base = rng.integers(0, 256, size=10_000, dtype=np.uint8).tobytes()
    target = rng.integers(0, 256, size=10_000, dtype=np.uint8).tobytes()
    assert len(delta.encode(target, base)) <= len(target) + 16


def test_varint():
    out = bytearray()
    for v in [0, 1, 127, 128, 300, 2**21, 2**40]:
        out.clear()
        delta._write_varint(out, v)
        got, pos = delta._read_varint(bytes(out), 0)
        assert got == v and pos == len(out)
