"""repro.api layered surface: registry/config round-trip, staged-detector
compat shim (bit-identical to the v0 interleaved protocol), stream-session
IngestReport accounting, and container-backend restore fidelity."""
import numpy as np
import pytest

from repro import api
from repro.core import baselines, chunking, context_model, features, pipeline, similarity
from repro.data import workloads

CCFG = chunking.ChunkerConfig(avg_size=8192)
AVG = 8192


@pytest.fixture(scope="module")
def versions():
    return workloads.make_workload(
        "sql_dump", workloads.WorkloadConfig(base_size=1 << 20, versions=3))


def _card_direct():
    return pipeline.CARDDetector(
        feat_cfg=features.FeatureConfig(k=32, m=64, n=2),
        model_cfg=context_model.ContextModelConfig(m=64, d=50, steps=60),
        use_kernel=False)


def _card_cfg(extra=None):
    d = {"detector": "card",
         "detector_args": {"feat": {"k": 32, "m": 64, "n": 2},
                           "model": {"m": 64, "d": 50, "steps": 60},
                           "use_kernel": False},
         "chunker_args": {"avg_size": AVG}}
    d.update(extra or {})
    return api.DedupConfig.from_dict(d)


def _stat_tuple(s):
    return (s.bytes_in, s.bytes_stored, s.chunks, s.dup_chunks,
            s.delta_chunks, s.raw_chunks)


def _run_store(store, versions):
    store.fit(versions[:1])
    for v in versions:
        store.ingest(v)
    return store.stats


# --- module docstring quick start --------------------------------------------

def test_api_quickstart_docstring_runs():
    """The repro.api docstring's quick-start snippet must execute verbatim
    (it drifted from the real session API once; never again)."""
    import re
    import textwrap

    match = re.search(r"Quick start:\n\n((?:    .*\n|\n)+)", api.__doc__)
    assert match, "quick-start block missing from repro.api docstring"
    snippet = textwrap.dedent(match.group(1))
    for call in ("build_store", "open_stream", "restore", "delete",
                 "collect", "compact"):
        assert call in snippet
    rng = np.random.default_rng(0)
    namespace = {"first_version":
                 rng.integers(0, 256, 96 * 1024, dtype=np.uint8).tobytes()}
    exec(compile(snippet, "<repro.api quick start>", "exec"), namespace)
    store = namespace["store"]
    assert store.stats.reclaimed_bytes > 0      # the reclaim really happened
    assert store.stats.live_bytes == 0


# --- registry + config construction -----------------------------------------

def test_registry_lists_builtins():
    assert {"card", "finesse", "n-transform", "dedup-only"} <= set(
        api.available_detectors())
    assert {"exact", "banded-lsh"} <= set(api.available_indexes())
    assert "fastcdc" in api.available_chunkers()
    assert {"memory", "file"} <= set(api.available_backends())
    with pytest.raises(KeyError, match="available"):
        api.get_detector("no-such-detector")


def test_config_round_trips_and_rejects_unknown_keys():
    cfg = _card_cfg()
    assert api.DedupConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="unknown"):
        api.DedupConfig.from_dict({"detecter": "card"})


@pytest.mark.parametrize("kind", ["card", "finesse", "n-transform", "dedup-only"])
def test_all_detectors_constructible_via_config(kind, versions):
    cfg = api.DedupConfig.from_dict(
        {"detector": kind,
         "detector_args": {"use_kernel": False} if kind == "card" else {},
         "chunker_args": {"avg_size": AVG}})
    store = api.build_store(cfg)
    stats = _run_store(store, versions[:2])
    assert stats.chunks > 0
    assert store.restore(store.reports[0].handle) == versions[0]


def test_config_path_matches_direct_construction(versions):
    """DedupConfig.from_dict -> build_store gives the same detection output
    as direct constructor calls (the context model is seeded)."""
    direct = pipeline.run_workload(_card_direct(), versions, CCFG)
    built = _run_store(api.build_store(_card_cfg()), versions)
    assert _stat_tuple(direct) == _stat_tuple(built)


def test_index_is_a_config_knob(versions):
    """exact vs banded-LSH selected declaratively; banding stays close."""
    exact = _run_store(api.build_store(_card_cfg()), versions)
    banded_cfg = _card_cfg()
    banded_cfg.detector_args["index"] = "banded-lsh"
    banded = _run_store(api.build_store(banded_cfg), versions)
    assert isinstance(api.build_detector(banded_cfg).index,
                      similarity.BandedLSHIndex)
    assert banded.dcr >= 0.9 * exact.dcr


# --- staged protocol + v0 compat shim ---------------------------------------

class _V0SuperFeatureDetector:
    """The pre-refactor monolithic FirstFit loop, verbatim: interleaved
    query/insert against the shared index. The staged overlay in
    SuperFeatureDetector.score must reproduce this bit-identically."""

    def __init__(self, scheme, name):
        self._scheme = scheme
        self.name = name
        self._index = baselines.SuperFeatureIndex()

    def fit(self, training_streams, cfg):
        pass

    def detect(self, chunks, ids, is_new, stream_hashes):
        out = np.full(len(chunks), -1, np.int64)
        for i, ck in enumerate(chunks):
            sfs = self._scheme.super_features(ck.data)
            if is_new[i]:
                hit = self._index.query(sfs)
                if hit is not None and hit != ids[i]:
                    out[i] = hit
            self._index.insert(sfs, int(ids[i]))
        return out


@pytest.mark.parametrize("scheme_cls,name", [(baselines.Finesse, "finesse"),
                                             (baselines.NTransform, "n-transform")])
def test_staged_firstfit_bit_identical_to_v0(scheme_cls, name, versions):
    staged = pipeline.SuperFeatureDetector(scheme_cls(), name)
    v0 = _V0SuperFeatureDetector(scheme_cls(), name)
    s_new = pipeline.run_workload(staged, versions, CCFG)
    s_old = pipeline.run_workload(v0, versions, CCFG)
    assert _stat_tuple(s_new) == _stat_tuple(s_old)
    assert staged._index._tables == v0._index._tables


def test_legacy_detect_shim_matches_staged(versions):
    """Calling the v0 .detect() surface equals running the staged stages —
    and a legacy-only wrapper goes through run_detect's fallback."""

    class LegacyOnly:
        def __init__(self, inner):
            self._inner = inner
            self.name = inner.name

        def fit(self, streams, cfg):
            self._inner.fit(streams, cfg)

        def detect(self, chunks, ids, is_new, stream_hashes):
            return self._inner.detect(chunks, ids, is_new, stream_hashes)

    staged = pipeline.run_workload(_card_direct(), versions, CCFG)
    legacy = pipeline.run_workload(LegacyOnly(_card_direct()), versions, CCFG)
    assert _stat_tuple(staged) == _stat_tuple(legacy)
    assert staged.dcr == legacy.dcr


def test_score_does_not_mutate_index(versions):
    det = _card_direct()
    det.fit(versions[:1], CCFG)
    stream = versions[0]
    buf = np.frombuffer(stream, dtype=np.uint8)
    from repro.core import hashing
    hashes = hashing.gear_hashes_np(buf)
    chunks = chunking.chunk_stream(stream, CCFG, hashes=hashes)
    ids = np.arange(len(chunks), dtype=np.int64)
    batch = api.DetectBatch(chunks=chunks, ids=ids,
                            is_new=np.ones(len(chunks), bool),
                            stream_hashes=hashes)
    feats = det.extract(batch)
    r1 = det.score(feats, batch)
    assert len(det.index) == 0          # pure: nothing admitted yet
    r2 = det.score(feats, batch)
    assert np.array_equal(r1.base_ids, r2.base_ids)
    det.observe(feats, batch)
    assert len(det.index) == len(chunks)


# --- stream sessions + IngestReport -----------------------------------------

def test_ingest_reports_sum_to_store_stats(versions):
    store = api.build_store(_card_cfg())
    store.fit(versions[:1])
    reports = []
    for v in versions:
        with store.open_stream() as session:
            session.write(v[: len(v) // 2])
            session.write(v[len(v) // 2:])
        reports.append(store.reports[-1])
    s = store.stats
    for field in ("bytes_in", "bytes_stored", "chunks", "dup_chunks",
                  "delta_chunks", "raw_chunks", "detect_seconds",
                  "chunk_seconds", "delta_seconds"):
        assert sum(getattr(r, field) for r in reports) == pytest.approx(
            getattr(s, field)), field
    assert [r.handle for r in reports] == [0, 1, 2]
    for r, v in zip(reports, versions):
        assert r.bytes_in == len(v)
        assert store.restore(r.handle) == v


def test_failed_commit_admits_nothing_to_index(versions):
    """Backend write failure mid-commit must leave the detector index
    untouched (observe is deferred past storage for staged detectors)."""

    class ExplodingBackend(api.InMemoryBackend):
        def put_raw(self, cid, data):
            raise OSError("disk full")

        def put_delta(self, cid, base, patch, data=None):
            raise OSError("disk full")

    store = api.DedupStore(pipeline.finesse_detector(), CCFG,
                           backend=ExplodingBackend())
    store.fit(versions[:1])
    session = store.open_stream()
    session.write(versions[0])
    with pytest.raises(OSError, match="disk full"):
        session.commit()
    assert store.detector._index._tables == []   # nothing admitted
    assert store.stats.chunks == 0
    assert store.backend.num_streams() == 0
    assert session.report is None


def test_session_report_available_after_context_exit(versions):
    store = api.build_store(_card_cfg())
    store.fit(versions[:1])
    with store.open_stream() as session:
        session.write(versions[0])
    assert session.report is not None
    assert session.report.handle == 0
    assert session.report.bytes_in == len(versions[0])


def test_aborted_session_leaves_no_trace(versions):
    store = api.build_store(_card_cfg())
    store.fit(versions[:1])
    session = store.open_stream()
    session.write(versions[0])
    session.abort()
    assert store.stats.chunks == 0
    assert store.backend.num_streams() == 0
    with pytest.raises(RuntimeError):
        session.commit()
    # a session abandoned by an exception also admits nothing
    with pytest.raises(RuntimeError, match="boom"):
        with store.open_stream() as s2:
            s2.write(versions[0])
            raise RuntimeError("boom")
    assert store.stats.chunks == 0 and len(store.detector.index) == 0


# --- container backends ------------------------------------------------------

def test_file_backend_restore_byte_identical(tmp_path, versions):
    cfg = api.DedupConfig.from_dict(
        {"detector": "finesse", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    store.fit(versions[:1])
    handles = []
    for v in versions:
        session = store.open_stream()
        session.write(v)
        handles.append(session.commit().handle)
    assert store.stats.delta_chunks > 0     # delta records actually on disk
    for h, v in zip(handles, versions):
        assert store.restore(h) == v
    store.close()

    # reopen from disk only: a fresh backend must materialize delta chains
    reopened = api.FileBackend(tmp_path)
    assert reopened.num_streams() == len(versions)
    for h, v in zip(handles, versions):
        got = b"".join(reopened.get(c) for c in reopened.recipe(h))
        assert got == v
    reopened.close()


def test_reopened_store_never_shadows_old_chunk_ids(tmp_path, versions):
    """A store opened on an existing file backend must seed its id counter
    past the persisted chunks, or new ingests corrupt old streams."""
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    first = api.build_store(cfg)
    first.ingest(versions[0])
    h0 = first.reports[-1].handle
    first.close()

    second = api.build_store(cfg)           # same dir, fresh store
    second.ingest(versions[1])
    h1 = second.reports[-1].handle
    assert second.restore(h1) == versions[1]
    assert second.restore(h0) == versions[0]   # old stream intact
    second.close()


def test_memory_and_file_backends_agree(tmp_path, versions):
    mem = api.build_store(_card_cfg())
    fil = api.build_store(_card_cfg(
        {"backend": "file", "backend_args": {"path": str(tmp_path)}}))
    s_mem = _run_store(mem, versions[:2])
    s_fil = _run_store(fil, versions[:2])

    def normalized(stats, store):
        # bytes_stored includes the backend-reported per-record overhead
        # (25-byte log headers on file, none in dicts); strip it so the
        # payload accounting must still agree bit-for-bit
        records = stats.delta_chunks + stats.raw_chunks
        t = _stat_tuple(stats)
        return (t[0], t[1] - records * store.backend.record_overhead,
                *t[2:])

    assert normalized(s_mem, mem) == normalized(s_fil, fil)
    fil.close()


def test_file_backend_survives_torn_tail(tmp_path, versions):
    """kill -9 mid-commit tears the log/recipe tails; reopen must drop the
    torn (never-reported) record, keep every committed stream, and keep
    the directory appendable."""
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    store.ingest(versions[0])
    h0 = store.reports[-1].handle
    store.ingest(versions[1])
    store.close()

    log = tmp_path / "chunks.log"
    recipes = tmp_path / "recipes.jsonl"
    log.write_bytes(log.read_bytes()[:-11])             # torn payload
    recipes.write_bytes(recipes.read_bytes()[:-5])      # torn JSON line

    reopened = api.build_store(cfg)
    assert reopened.backend.num_streams() == 1          # stream 1 tail torn away
    assert reopened.restore(h0) == versions[0]
    reopened.ingest(versions[2])                        # appends still work...
    h2 = reopened.reports[-1].handle
    assert reopened.restore(h2) == versions[2]
    reopened.close()
    third = api.FileBackend(tmp_path)                   # ...and re-scan cleanly
    assert b"".join(third.get(c) for c in third.recipe(h2)) == versions[2]
    third.close()


def test_file_backend_torn_newline_only(tmp_path, versions):
    """A final recipe line that parses but lost only its newline is still
    torn — keeping it would merge the next append onto the same line and
    destroy every recipe on the reopen after that."""
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    store.ingest(versions[0])
    h0 = store.reports[-1].handle
    store.ingest(versions[1])
    store.close()

    recipes = tmp_path / "recipes.jsonl"
    recipes.write_bytes(recipes.read_bytes()[:-1])      # shear the newline

    second = api.build_store(cfg)
    assert second.backend.num_streams() == 1            # stream 1 dropped
    second.ingest(versions[2])
    h2 = second.reports[-1].handle
    second.close()

    third = api.build_store(cfg)                        # the critical reopen
    assert third.backend.num_streams() == 2
    assert third.restore(h0) == versions[0]
    assert third.restore(h2) == versions[2]
    third.close()


def test_custom_chunker_registers_and_runs(versions):
    """The chunker seam is real: a registered fixed-size chunker flows
    through build_store and the whole ingest/restore path."""
    from repro.core import hashing

    class FixedSizeChunker:
        def __init__(self, size=8192):
            self.size = size

        def chunk(self, stream):
            hashes = hashing.gear_hashes_np(np.frombuffer(stream, np.uint8))
            chunks = [chunking.Chunk(off, len(stream[off:off + self.size]),
                                     stream[off:off + self.size])
                      for off in range(0, len(stream), self.size)]
            return chunks, hashes

    if "fixed" not in api.available_chunkers():
        api.register_chunker("fixed")(FixedSizeChunker)
    cfg = api.DedupConfig.from_dict(
        {"detector": "finesse", "chunker": "fixed",
         "chunker_args": {"size": 8192}})
    store = api.build_store(cfg)
    store.fit(versions[:1])
    stats = _run_store(store, versions[:2])
    assert stats.chunks == sum(-(-len(v) // 8192) for v in versions[:2])
    assert stats.dup_chunks > 0
    assert store.restore(store.reports[1].handle) == versions[1]


def test_builtin_registration_survives_failed_import(monkeypatch):
    """A failing builtin import must not permanently empty the registries."""
    import builtins
    from repro.api import registry as reg

    monkeypatch.setattr(reg, "_builtins_loaded", False)
    real_import = builtins.__import__

    def boom(name, *args, **kwargs):
        if name == "repro.core":
            raise ImportError("transient")
        return real_import(name, *args, **kwargs)

    monkeypatch.setattr(builtins, "__import__", boom)
    with pytest.raises(ImportError, match="transient"):
        reg.get_detector("card")
    monkeypatch.setattr(builtins, "__import__", real_import)
    assert "card" in reg.available_detectors()          # recovers


def test_checkpoint_store_has_no_private_reach_through():
    import inspect
    from repro.checkpoint import dedup_store
    assert "_recipes" not in inspect.getsource(dedup_store)


# --- banded LSH batch insert -------------------------------------------------

def test_banded_insert_batch_matches_serial_insert():
    rng = np.random.default_rng(3)
    feats = rng.standard_normal((64, 50)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    ids = np.arange(64, dtype=np.int64)

    a = similarity.BandedLSHIndex(50)
    b = similarity.BandedLSHIndex(50)
    a.insert_batch(feats, ids)
    for f, cid in zip(feats, ids):      # v0 path: one insert per row
        b._feats[int(cid)] = np.asarray(f, np.float32)
        signs = (np.einsum("bkd,d->bk", b._planes, f) > 0)
        weights = (1 << np.arange(b.band_bits, dtype=np.uint64))
        keys = (signs.astype(np.uint64) * weights).sum(axis=1)
        for band, key in enumerate(keys):
            b._tables[band].setdefault(int(key), []).append(int(cid))
    assert a._tables == b._tables
    qid_a, qs_a = a.query(feats[:8])
    qid_b, qs_b = b.query(feats[:8])
    assert np.array_equal(qid_a, qid_b)
    assert np.allclose(qs_a, qs_b)
