"""Refcount invariants under arbitrary ingest/delete/collect/compact
interleavings (hypothesis; DESIGN.md §7): every live recipe restores
byte-identical, no live chunk's base chain references a swept chunk, and
the incremental refcounts match a from-scratch rebuild."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import api
from test_lifecycle import CHUNK, _edit, _ingest, _rand  # sibling module


def _version_pool():
    versions = [_rand(16 * CHUNK, seed=100)]
    for i in range(4):
        versions.append(_edit(versions[-1], seed=101 + i, nedits=8))
    return versions


POOL = _version_pool()


@given(ops=st.lists(st.integers(min_value=0, max_value=63),
                    min_size=1, max_size=14))
@settings(max_examples=15, deadline=None)
def test_reclamation_interleaving_property(ops):
    cfg = api.DedupConfig.from_dict(
        {"detector": "finesse", "chunker_args": {"avg_size": CHUNK}})
    store = api.build_store(cfg)
    store.fit(POOL[:1])
    model = {}                       # handle -> expected bytes
    for i, op in enumerate(ops):
        kind = op % 4
        if kind in (0, 1):                           # ingest (weighted 2x)
            data = POOL[(op // 4 + i) % len(POOL)]
            model[_ingest(store, data)] = data
        elif kind == 2 and model:                    # delete some live stream
            handle = sorted(model)[(op // 4) % len(model)]
            del model[handle]
            store.delete(handle)
        elif kind == 3:
            store.collect()
            store.compact()

    backend = store.backend
    for handle, data in model.items():
        assert store.restore(handle) == data
    for handle in backend.live_handles():
        for cid in backend.recipe(handle):
            cur = cid
            while cur >= 0:                          # full base chain present
                assert backend.contains(cur)
                cur = backend.base_of(cur)
    rebuilt = api.RefcountTable.rebuild(backend)
    refs = store._refs
    assert (rebuilt.live_bytes, rebuilt.pinned_bytes, rebuilt.dead_bytes) == (
        refs.live_bytes, refs.pinned_bytes, refs.dead_bytes)
    assert sorted(rebuilt.dead_cids()) == sorted(refs.dead_cids())
