"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (launch/dryrun.py,
ShapeDtypeStruct — no allocation), per the assignment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.models import make_model
from repro.train import make_train_step
from repro.train.step import init_state

B, S = 2, 64


def _extras(cfg, batch=B):
    ex = {}
    if cfg.family == "vlm":
        ex["images"] = jnp.zeros((batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        ex["frames"] = jnp.zeros((batch, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return ex


def _batch(cfg, rng):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    return dict({"tokens": toks[:, :-1], "labels": toks[:, 1:]}, **_extras(cfg))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = make_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(model.forward)(params, batch["tokens"],
                                         _extras(cfg) or None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tx = optim.adamw(1e-3)
    step = jax.jit(make_train_step(model, tx))
    state = init_state(params, tx)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0].astype(jnp.float32) - l[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), state.params, state2.params),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cache = model.init_cache(batch=B, max_len=32)
    tok = jnp.ones((B, 1), jnp.int32)
    dec = jax.jit(model.decode_step)
    extras = _extras(cfg) or None
    logits, cache = dec(params, tok, cache, extras)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = dec(params, tok, cache, extras)
    assert int(cache["pos"]) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_param_counts_match_analytic():
    """Analytic count (used in roofline MODEL_FLOPS) vs actual tree."""
    for arch in ["granite-8b", "mamba2-130m", "qwen3-moe-30b-a3b"]:
        cfg = get_config(arch).reduced()
        model = make_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(shapes))
        # norms/small vectors allowed to drift; structure must agree closely
        assert abs(actual - cfg.param_count()) / actual < 0.05, arch


def test_mamba_train_matches_decode():
    """Chunked SSD teacher-forcing == step-by-step recurrence."""
    cfg = get_config("mamba2-130m").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab_size)
    full, _ = model.forward(params, toks, remat=False)
    cache = model.init_cache(batch=1, max_len=16)
    outs = []
    for i in range(12):
        logit, cache = model.decode_step(params, toks[:, i:i + 1], cache)
        outs.append(logit)
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_attention_prefill_matches_decode():
    """Dense-attention forward == incremental KV-cache decode."""
    cfg = get_config("granite-8b").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 10), 0, cfg.vocab_size)
    full, _ = model.forward(params, toks, remat=False)
    cache = model.init_cache(batch=2, max_len=16)
    outs = []
    for i in range(10):
        logit, cache = model.decode_step(params, toks[:, i:i + 1], cache)
        outs.append(logit)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(jnp.stack(outs, 1), np.float32),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_dense():
    from repro.models import layers as L
    rng = jax.random.PRNGKey(6)
    q = jax.random.normal(rng, (2, 300, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(7), (2, 300, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(8), (2, 300, 4, 32))
    dense = L._dense_attention(q, k, v, causal=True)
    chunked = L._chunked_attention(q, k, v, causal=True, kv_block=128)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)
