"""Initial feature extraction (Algorithm 1): determinism, robustness,
np/jnp path agreement."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features, hashing


def _rand_chunks(seed, n=8, lo=2000, hi=30000):
    rng = np.random.Generator(np.random.PCG64(seed))
    return [rng.integers(0, 256, size=int(s), dtype=np.uint8).tobytes()
            for s in rng.integers(lo, hi, size=n)]


def test_deterministic_and_normalized():
    chunks = _rand_chunks(1)
    ext = features.FeatureExtractor(use_kernel=False)
    f1, f2 = ext(chunks), ext(chunks)
    assert np.array_equal(f1, f2)
    np.testing.assert_allclose(np.linalg.norm(f1, axis=1), 1.0, rtol=1e-5)


def test_kernel_path_matches_jnp():
    chunks = _rand_chunks(2)
    cfg = features.FeatureConfig()
    fk = features.FeatureExtractor(cfg, use_kernel=True)(chunks)
    fj = features.FeatureExtractor(cfg, use_kernel=False)(chunks)
    np.testing.assert_allclose(fk, fj, atol=1e-5)


def test_maxgear_insert_robustness():
    """The paper's core motivation: features must survive shift edits."""
    rng = np.random.Generator(np.random.PCG64(3))
    base = rng.integers(0, 256, size=16384, dtype=np.uint8)
    ins = np.concatenate([base[:4000],
                          rng.integers(0, 256, size=5, dtype=np.uint8),
                          base[4000:]])
    rnd = rng.integers(0, 256, size=16384, dtype=np.uint8)
    ext = features.FeatureExtractor(use_kernel=False)
    f = ext([base.tobytes(), ins.tobytes(), rnd.tobytes()])
    assert f[0] @ f[1] > 0.95          # 5-byte insert barely moves the feature
    assert abs(f[0] @ f[2]) < 0.35     # random content is far


def test_poly_ablation_is_fragile():
    """Documents WHY the LSH choice matters (DESIGN.md §1 adaptation)."""
    rng = np.random.Generator(np.random.PCG64(4))
    base = rng.integers(0, 256, size=16384, dtype=np.uint8)
    ins = np.concatenate([base[:4000],
                          rng.integers(0, 256, size=5, dtype=np.uint8),
                          base[4000:]])
    poly = features.FeatureExtractor(
        features.FeatureConfig(lsh="poly"), use_kernel=False)
    f = poly([base.tobytes(), ins.tobytes()])
    maxg = features.FeatureExtractor(use_kernel=False)
    g = maxg([base.tobytes(), ins.tobytes()])
    assert g[0] @ g[1] > f[0] @ f[1] + 0.3


def test_chunk_size_sensitivity():
    """Paper §3 (Chunk_H): equal-split content features degrade under big
    truncations — the motivation for the chunk-context model — but must
    survive small tail deletions (sub-chunk windows barely move)."""
    rng = np.random.Generator(np.random.PCG64(5))
    base = rng.integers(0, 256, size=16384, dtype=np.uint8)
    small_cut = base[:16200]   # ~1% tail deletion
    big_cut = base[:12000]     # ~27% tail deletion
    ext = features.FeatureExtractor(use_kernel=False)
    f = ext([base.tobytes(), small_cut.tobytes(), big_cut.tobytes()])
    assert f[0] @ f[1] > 0.75          # robust to small size change
    assert f[0] @ f[1] > f[0] @ f[2]   # big truncation is the hard case


def test_jnp_maxgear_matches_np():
    chunks = _rand_chunks(6, n=5)
    k = 32
    sub_np = features.batch_subchunk_lsh_np(chunks, features.FeatureConfig(k=k))
    lmax = max(len(c) for c in chunks)
    gear = np.zeros((len(chunks), lmax), np.uint32)
    lens = np.array([len(c) for c in chunks], np.int32)
    for i, c in enumerate(chunks):
        gear[i, :len(c)] = hashing.gear_hashes_np(np.frombuffer(c, np.uint8))
    sub_j = np.asarray(features.batch_subchunk_maxgear_j(
        jnp.asarray(gear), jnp.asarray(lens), k))
    assert np.array_equal(sub_np, sub_j)


def test_jnp_poly_matches_np():
    chunks = _rand_chunks(7, n=5)
    k = 16
    cfg = features.FeatureConfig(k=k, lsh="poly")
    sub_np = features.batch_subchunk_lsh_np(chunks, cfg)
    lmax = max(len(c) for c in chunks)
    padded = np.zeros((len(chunks), lmax), np.uint8)
    lens = np.array([len(c) for c in chunks], np.int32)
    for i, c in enumerate(chunks):
        padded[i, :len(c)] = np.frombuffer(c, np.uint8)
    sub_j = np.asarray(features.batch_subchunk_poly_j(
        jnp.asarray(padded), jnp.asarray(lens), k))
    assert np.array_equal(sub_np, sub_j)


def test_stream_hash_reuse_identical():
    """Features computed from the chunker's stream scan == per-chunk scan."""
    rng = np.random.Generator(np.random.PCG64(8))
    stream = rng.integers(0, 256, size=100_000, dtype=np.uint8)
    from repro.core import chunking
    h = hashing.gear_hashes_np(stream)
    cks = chunking.chunk_stream(stream.tobytes(), chunking.ChunkerConfig(avg_size=8192), hashes=h)
    ext = features.FeatureExtractor(use_kernel=False)
    offs = np.asarray([c.offset for c in cks])
    f1 = ext([c.data for c in cks], h, offs)
    f2 = ext([c.data for c in cks])
    np.testing.assert_allclose(f1, f2, atol=1e-6)
