"""Checkpointing: atomic commits, byte-exact restore (incl. bf16), elastic
resharding, dedup-store DCR, and the checkpoint/restart driver."""
import json
import subprocess
import sys
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import DedupCheckpointStore, latest_step, restore, save
from repro.checkpoint import store as ckpt_store


def _tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": (jax.random.normal(k, (64, 128), jnp.float32) * scale),
        "b": jnp.arange(128, dtype=jnp.bfloat16),
        "nested": {"step": jnp.asarray(7, jnp.int32),
                   "m": jnp.ones((3, 5, 7), jnp.bfloat16) * scale},
    }


def _trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
               for x, y in zip(fa, fb))


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, t, step=3)
    got = restore(tmp_path, t)
    assert _trees_equal(t, got)
    assert latest_step(tmp_path) == 3


def test_multiple_steps_and_latest(tmp_path):
    for s in (1, 5, 10):
        save(tmp_path, _tree(seed=s), step=s)
    assert latest_step(tmp_path) == 10
    got = restore(tmp_path, _tree(), step=5)
    assert _trees_equal(_tree(seed=5), got)


def test_corruption_detected(tmp_path):
    t = _tree()
    d = save(tmp_path, t, step=1)
    victim = sorted(d.glob("leaf_*.bin"))[0]
    raw = bytearray(victim.read_bytes())
    raw[0] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="digest"):
        restore(tmp_path, t, step=1)


def test_tmp_dir_never_readable(tmp_path):
    """A .tmp directory (simulated crash mid-write) is not a checkpoint."""
    t = _tree()
    save(tmp_path, t, step=2)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert latest_step(tmp_path) == 2


def _ckpt_tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"params": {"w": jax.random.normal(k1, (512, 1024), jnp.bfloat16),
                       "e": jax.random.normal(k2, (1024, 256), jnp.bfloat16)},
            "mu": jax.random.normal(k1, (256, 512), jnp.float32) * 0.01,
            "step": jnp.asarray(7, jnp.int32)}


def _run_drift(sigma, steps=4):
    store = DedupCheckpointStore()
    rng = np.random.default_rng(0)
    tree = _ckpt_tree(1)
    history = []
    for i in range(steps):
        tree = jax.tree_util.tree_map(
            lambda x: x + jnp.asarray(rng.standard_normal(x.shape) * sigma,
                                      x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
        store.save(tree, step=i)
        history.append(tree)
    return store, history


def test_dedup_store_dcr_and_restore():
    """Successive similar checkpoints dedup/delta (the paper's technique
    applied to training state); restore is value-exact."""
    store, history = _run_drift(1e-3)
    assert store.stats.dcr > 1.15, store.stats
    assert store.stats.delta_chunks > 0
    got = store.restore(_ckpt_tree(0), step=2)
    assert _trees_equal(history[2], got)


def test_dedup_store_dcr_improves_with_smaller_updates():
    """Late-training (small-update) checkpoints compress better — the
    production motivation for frequent cheap checkpoints."""
    coarse, _ = _run_drift(1e-3)
    fine, _ = _run_drift(1e-5)
    assert fine.stats.dcr > coarse.stats.dcr * 1.3, \
        (fine.stats.dcr, coarse.stats.dcr)


@pytest.mark.subprocess_mesh
def test_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save, restore
from repro.launch.mesh import make_mesh
n = %d
mesh = make_mesh((n,), ("data",))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
mode = sys.argv[1]
if mode == "save":
    save(%r, {"x": xs}, step=1)
else:
    got = restore(%r, {"x": xs}, step=1)
    assert np.array_equal(np.asarray(got["x"]), np.asarray(x))
    assert got["x"].sharding.num_devices == n
    print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    p1 = subprocess.run([sys.executable, "-c",
                         script % (8, 8, str(tmp_path), str(tmp_path)), "save"],
                        capture_output=True, text=True, env=env, cwd=Path.cwd())
    assert p1.returncode == 0, p1.stderr[-2000:]
    p2 = subprocess.run([sys.executable, "-c",
                         script % (4, 4, str(tmp_path), str(tmp_path)), "load"],
                        capture_output=True, text=True, env=env, cwd=Path.cwd())
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "ELASTIC_OK" in p2.stdout


@pytest.mark.subprocess_mesh
def test_restart_after_injected_failure(tmp_path):
    """Worker crashes at step 12; supervisor restarts; run completes from
    the last committed checkpoint."""
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.supervisor", "--retries", "2", "--",
           sys.executable, "-m", "repro.launch.train",
           "--arch", "mamba2-130m", "--steps", "20", "--batch", "2",
           "--seq", "32", "--checkpoint-every", "5",
           "--ckpt-dir", str(tmp_path / "run"), "--fail-at", "12"]
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=Path.cwd(), timeout=540)
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-2000:]
    assert "failure-injection" in p.stdout
    assert "[resume] restored step 10" in p.stdout
    assert "[done] 20 steps" in p.stdout
